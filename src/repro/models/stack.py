"""Composable decoder/encoder stack.

The layer pattern (config.pattern, e.g. ``"LLLLLG"`` → gemma3) defines a
repeating *group*; parameters are stacked over groups and the stack is
``lax.scan``-ned (LoopPolicy = no-unroll, paper P1) or Python-unrolled
(``scan_layers=False``). 'S' blocks use one *shared* parameter set
(Zamba2) captured as a scan constant — weight sharing as a compile-time
structural constant is the purest P3 exploit in the pool.
"""
from __future__ import annotations

import copy
import functools
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention_vjp import flash_mha, local_mha
from .config import ModelConfig
from .kernel_policy import DEFAULT_KERNELS, KernelPolicy, fit_block
from .layers import (
    decode_attention_jax,
    gated_mlp,
    layer_norm,
    linear,
    mrope,
    rms_norm,
    rope,
)
from .moe import moe_mlp
from .ssm import (
    MambaState,
    RWKVState,
    init_mamba2,
    init_rwkv6,
    mamba2_mix,
    rwkv6_channel_mix,
    rwkv6_time_mix,
)


class Par:
    """Parallelism context. The default is a single-device no-op; the
    distribution layer overrides hooks to add sharding constraints and a
    shard_map'd MoE. Model code never imports mesh machinery.

    ``kernels`` carries the :class:`KernelPolicy` — the autotuned choice
    of prefill attention / RWKV scan kernel — so kernel selection rides
    the same context object as parallelism and the model code stays free
    of engine imports."""

    kernels: KernelPolicy = DEFAULT_KERNELS

    def with_kernels(self, policy: Optional[KernelPolicy]) -> "Par":
        if policy is None:
            return self
        out = copy.copy(self)
        out.kernels = KernelPolicy(*policy).validate()
        return out

    def constraint(self, x, kind: str):
        return x

    def moe(self, x, p, cfg: ModelConfig):
        B, T, D = x.shape
        y = moe_mlp(x.reshape(B * T, D), p, top_k=cfg.top_k, act=cfg.act,
                    capacity_factor=cfg.capacity_factor)
        return y.reshape(B, T, D)


DEFAULT_PAR = Par()


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ================================================================= init =====

def _sc(key, shape, fan_in, dtype):
    return (jax.random.normal(key, shape, jnp.float32)
            * fan_in ** -0.5).astype(dtype)


def init_attn(key, cfg: ModelConfig) -> dict:
    D, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    dt = _dtype(cfg)
    p = {
        "wq": _sc(ks[0], (D, H * Dh), D, dt),
        "wk": _sc(ks[1], (D, Hkv * Dh), D, dt),
        "wv": _sc(ks[2], (D, Hkv * Dh), D, dt),
        "wo": _sc(ks[3], (H * Dh, D), H * Dh, dt),
    }
    if cfg.qkv_bias:
        p.update(bq=jnp.zeros((H * Dh,), jnp.float32),
                 bk=jnp.zeros((Hkv * Dh,), jnp.float32),
                 bv=jnp.zeros((Hkv * Dh,), jnp.float32))
    return p


def init_mlp(key, cfg: ModelConfig) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = _dtype(cfg)
    p = {"wg": _sc(ks[0], (D, F), D, dt),
         "wd": _sc(ks[2], (F, D), F, dt)}
    if cfg.mlp_gated:
        p["wu"] = _sc(ks[1], (D, F), D, dt)
    return p


def init_moe(key, cfg: ModelConfig) -> dict:
    D, E = cfg.d_model, cfg.n_experts
    Fe = cfg.moe_d_ff or cfg.d_ff
    ks = jax.random.split(key, 7)
    dt = _dtype(cfg)
    p = {
        "router": _sc(ks[0], (D, E), D, jnp.float32),
        "wg": _sc(ks[1], (E, D, Fe), D, dt),
        "wu": _sc(ks[2], (E, D, Fe), D, dt),
        "wd": _sc(ks[3], (E, Fe, D), Fe, dt),
    }
    if cfg.n_shared_experts:
        Fs = Fe * cfg.n_shared_experts
        p.update(shared_wg=_sc(ks[4], (D, Fs), D, dt),
                 shared_wu=_sc(ks[5], (D, Fs), D, dt),
                 shared_wd=_sc(ks[6], (Fs, D), Fs, dt))
    return p


def init_block(key, kind: str, cfg: ModelConfig) -> dict:
    D = cfg.d_model
    ks = jax.random.split(key, 4)
    if kind in ("A", "L", "S"):
        p = {"ln1": jnp.zeros((D,), jnp.float32),
             "ln2": jnp.zeros((D,), jnp.float32),
             "attn": init_attn(ks[0], cfg)}
        p["mlp"] = (init_moe(ks[1], cfg)
                    if cfg.n_experts and kind != "S" else init_mlp(ks[1], cfg))
        return p
    if kind == "M":
        return {"ln1": jnp.zeros((D,), jnp.float32),
                "mamba": init_mamba2(ks[0], D, ssm_state=cfg.ssm_state,
                                     head_dim=cfg.ssm_head_dim,
                                     conv_kernel=cfg.conv_kernel,
                                     dtype=_dtype(cfg))}
    if kind == "R":
        return {"ln1": jnp.ones((D,), jnp.float32),
                "ln1b": jnp.zeros((D,), jnp.float32),
                "ln2": jnp.ones((D,), jnp.float32),
                "ln2b": jnp.zeros((D,), jnp.float32),
                "rwkv": init_rwkv6(ks[0], D, cfg.d_ff,
                                   head_dim=cfg.ssm_head_dim,
                                   dtype=_dtype(cfg))}
    raise ValueError(kind)


def init_params(cfg: ModelConfig, key=None) -> dict:
    key = key if key is not None else jax.random.PRNGKey(0)
    k_embed, k_groups, k_shared, k_head = jax.random.split(key, 4)
    dt = _dtype(cfg)
    params: Dict[str, Any] = {}
    if cfg.embed_inputs:
        params["embed"] = _sc(k_embed, (cfg.vocab_size, cfg.d_model),
                              cfg.d_model, dt)
    if cfg.prologue:
        pro_keys = jax.random.split(jax.random.fold_in(k_groups, 1),
                                    len(cfg.prologue))
        params["prologue"] = [
            {} if kind == "S" else init_block(pro_keys[i], kind, cfg)
            for i, kind in enumerate(cfg.prologue)]
    # per-position stacks over groups
    group_params: List[dict] = []
    pos_keys = jax.random.split(k_groups, len(cfg.pattern))
    for pos, kind in enumerate(cfg.pattern):
        if kind == "S":
            group_params.append({})  # shared weights live outside the stack
            continue
        gkeys = jax.random.split(pos_keys[pos], cfg.n_groups)
        group_params.append(
            jax.vmap(lambda k: init_block(k, kind, cfg))(gkeys))
    params["groups"] = group_params
    if "S" in cfg.pattern:
        params["shared"] = init_block(k_shared, "S", cfg)
    params["final_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
    if not cfg.tie_embeddings:
        params["head"] = _sc(k_head, (cfg.d_model, cfg.vocab_size),
                             cfg.d_model, dt)
    return params


# ================================================================ caches =====

def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Decode caches: {'pro': one per prologue block (unstacked),
    'grp': one per pattern position, stacked over groups}."""
    return {
        "pro": [jax.tree.map(lambda a: a[0], _position_cache(
            cfg, k, batch, max_len, 1)) for k in cfg.prologue],
        "grp": [_position_cache(cfg, k, batch, max_len, cfg.n_groups)
                for k in cfg.pattern],
    }


def _position_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                    ng: int):
    dt = _dtype(cfg)
    if kind in ("A", "S", "L", "M", "R"):
        if kind in ("A", "S"):
            S = max_len
            return {
                "k": jnp.zeros((ng, batch, S, cfg.n_kv_heads, cfg.head_dim), dt),
                "v": jnp.zeros((ng, batch, S, cfg.n_kv_heads, cfg.head_dim), dt),
            }
        elif kind == "L":
            S = min(cfg.window, max_len)
            return {
                "k": jnp.zeros((ng, batch, S, cfg.n_kv_heads, cfg.head_dim), dt),
                "v": jnp.zeros((ng, batch, S, cfg.n_kv_heads, cfg.head_dim), dt),
            }
        elif kind == "M":
            d_inner = 2 * cfg.d_model
            H = d_inner // cfg.ssm_head_dim
            return MambaState(
                ssm=jnp.zeros((ng, batch, H, cfg.ssm_state,
                               cfg.ssm_head_dim), jnp.float32),
                conv=jnp.zeros((ng, batch, cfg.conv_kernel - 1, d_inner), dt))
        elif kind == "R":
            N = cfg.ssm_head_dim
            H = cfg.d_model // N
            return RWKVState(
                wkv=jnp.zeros((ng, batch, H, N, N), jnp.float32),
                prev_tm=jnp.zeros((ng, batch, cfg.d_model), dt),
                prev_cm=jnp.zeros((ng, batch, cfg.d_model), dt))
    raise ValueError(kind)


# =============================================================== blocks =====

def _apply_rope(cfg, q, k, positions, pos3):
    if cfg.mrope_sections is not None and pos3 is not None:
        return (mrope(q, pos3, cfg.mrope_sections, cfg.rope_theta),
                mrope(k, pos3, cfg.mrope_sections, cfg.rope_theta))
    return (rope(q, positions, cfg.rope_theta, cfg.rope_dim),
            rope(k, positions, cfg.rope_theta, cfg.rope_dim))


def _prefill_attention(q, k, v, cfg: ModelConfig, kind: str,
                       pol: KernelPolicy):
    """Prefill/train attention dispatch over the policy's variant axis.

    q/k/v are (B, T, H, Dh); the Pallas kernel and the dense oracle both
    speak (B, H, T, Dh), so those paths transpose at the boundary."""
    window = cfg.window if kind == "L" and cfg.window is not None else None
    if pol.attention == "flash_jax":
        import os as _os
        bq = int(_os.environ.get("NNCG_FLASH_BQ", pol.block_q))
        bk = int(_os.environ.get("NNCG_FLASH_BK", pol.block_k))
        if window is not None:
            return local_mha(q, k, v, window, None, min(bq, 256))
        return flash_mha(q, k, v, cfg.causal, None, None, bq, bk)
    qh, kh, vh = (jnp.swapaxes(a, 1, 2) for a in (q, k, v))
    if pol.attention == "flash_pallas":
        from ..kernels.ops import flash_attention
        o = flash_attention(qh, kh, vh, causal=cfg.causal, window=window,
                            block_q=fit_block(qh.shape[2], pol.block_q),
                            block_k=fit_block(kh.shape[2], pol.block_k))
    else:  # "reference"
        from ..kernels.ref import attention_ref
        o = attention_ref(qh, kh, vh, causal=cfg.causal, window=window)
    return jnp.swapaxes(o, 1, 2)


def attention_block(x, p, cfg: ModelConfig, par: Par, kind: str, *,
                    positions, cache=None, pos=None, pos3=None):
    """Returns (y, new_cache). Handles train (no cache), prefill (cache
    write), and decode (T==1, cache read+write)."""
    B, T, D = x.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if cache is None and getattr(par, "ulysses_ok", lambda *_: False)(cfg, T):
        return par.ulysses_attention(x, p, cfg, kind, positions), None
    q = linear(x, p["wq"], p.get("bq")).reshape(B, T, H, Dh)
    k = linear(x, p["wk"], p.get("bk")).reshape(B, T, Hkv, Dh)
    v = linear(x, p["wv"], p.get("bv")).reshape(B, T, Hkv, Dh)
    q, k = _apply_rope(cfg, q, k, positions, pos3)
    q = par.constraint(q, "heads")
    k = par.constraint(k, "kv_heads")
    v = par.constraint(v, "kv_heads")

    new_cache = cache
    if cache is not None and T == 1:
        S = cache["k"].shape[1]
        ring = kind == "L" and cfg.window is not None
        slot = jnp.mod(pos, S) if ring else pos
        kc = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
        new_cache = {"k": kc, "v": vc}
        window = cfg.window if kind == "L" else None
        o = decode_attention_jax(q, kc, vc, pos, window=window, ring=ring)
    else:
        if cache is not None:  # prefill: populate the cache
            S = cache["k"].shape[1]
            if S >= T:
                kc = jax.lax.dynamic_update_slice(
                    cache["k"], k, (0, 0, 0, 0))
                vc = jax.lax.dynamic_update_slice(
                    cache["v"], v, (0, 0, 0, 0))
            else:  # ring cache smaller than prompt: keep last S, rotated
                kc = jnp.roll(k[:, -S:], T % S, axis=1)
                vc = jnp.roll(v[:, -S:], T % S, axis=1)
            new_cache = {"k": kc, "v": vc}
        o = _prefill_attention(q, k, v, cfg, kind,
                               getattr(par, "kernels", DEFAULT_KERNELS))
    o = par.constraint(o, "heads")
    y = linear(o.reshape(B, T, H * Dh), p["wo"])
    return y, new_cache


def mlp_block(x, p, cfg: ModelConfig, par: Par, kind: str):
    if cfg.n_experts and kind != "S":
        y = par.moe(x, p, cfg)  # (B,T,D); flattened inside the shard_map
    else:
        y = gated_mlp(x, p, cfg.act)
    return y


def apply_block(x, kind: str, p, cfg: ModelConfig, par: Par, *,
                positions, cache=None, pos=None, pos3=None):
    if kind in ("A", "L", "S"):
        h, new_cache = attention_block(
            rms_norm(x, p["ln1"], cfg.norm_eps), p["attn"], cfg, par, kind,
            positions=positions, cache=cache, pos=pos, pos3=pos3)
        x = x + h
        x = x + mlp_block(rms_norm(x, p["ln2"], cfg.norm_eps),
                          p["mlp"], cfg, par, kind)
        return x, new_cache
    if kind == "M":
        h, new_state = mamba2_mix(
            rms_norm(x, p["ln1"], cfg.norm_eps), p["mamba"],
            ssm_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim, state=cache)
        return x + h, new_state
    if kind == "R":
        h, wkv, prev_tm = rwkv6_time_mix(
            layer_norm(x, p["ln1"], p["ln1b"]), p["rwkv"],
            head_dim=cfg.ssm_head_dim, state=cache,
            constraint=lambda t: par.constraint(t, "ssm_heads"),
            scan=getattr(par, "kernels", DEFAULT_KERNELS).scan)
        x = x + h
        h, prev_cm = rwkv6_channel_mix(
            layer_norm(x, p["ln2"], p["ln2b"]), p["rwkv"],
            None if cache is None else cache.prev_cm)
        x = x + h
        return x, RWKVState(wkv=wkv, prev_tm=prev_tm, prev_cm=prev_cm)
    raise ValueError(kind)


# ================================================================ stack =====

def apply_stack(x, params, cfg: ModelConfig, par: Par, *,
                positions, caches=None, pos=None, pos3=None):
    """Run the full layer stack. Returns (x, new_caches)."""
    shared_p = params.get("shared")
    have_cache = caches is not None

    def one_block(x, kind, p, c):
        x = par.constraint(x, "activations")
        return apply_block(x, kind, p, cfg, par, positions=positions,
                           cache=c, pos=pos, pos3=pos3)

    if cfg.remat == "full":
        # per-BLOCK remat: backward replays one block at a time, so the
        # live residual set is O(one block), not O(group) — critical for
        # long repeating groups (gemma3: 17/31 blocks per group).
        one_block = jax.checkpoint(one_block, static_argnums=(1,))

    def group_body(x, group_slice, cache_slice):
        new_caches = []
        for i, kind in enumerate(cfg.pattern):
            p = shared_p if kind == "S" else group_slice[i]
            c = cache_slice[i] if have_cache else None
            x, nc = one_block(x, kind, p, c)
            new_caches.append(nc)
        return x, new_caches

    # prologue: unscanned blocks with their own (unstacked) params/caches
    new_pro = []
    for i, kind in enumerate(cfg.prologue):
        p = shared_p if kind == "S" else params["prologue"][i]
        c = caches["pro"][i] if have_cache else None
        x, nc = one_block(x, kind, p, c)
        new_pro.append(nc)

    grp_caches = caches["grp"] if have_cache else None
    if cfg.scan_layers:
        if have_cache:
            def scan_fn(carry, xs):
                gp, cs = xs
                return group_body(carry, gp, cs)
            x, new_grp = jax.lax.scan(scan_fn, x,
                                      (params["groups"], grp_caches))
        else:
            def scan_fn(carry, xs):
                y, _ = group_body(carry, xs, [None] * len(cfg.pattern))
                return y, ()
            x, _ = jax.lax.scan(scan_fn, x, params["groups"])
            return x, None
    else:
        # unrolled (P1 level-0 analogue)
        acc = [[] for _ in cfg.pattern]
        for g in range(cfg.n_groups):
            gp = jax.tree.map(lambda a: a[g], params["groups"])
            cs = (jax.tree.map(lambda a: a[g], grp_caches) if have_cache
                  else [None] * len(cfg.pattern))
            x, ncs = group_body(x, gp, cs)
            if have_cache:
                for i, nc in enumerate(ncs):
                    acc[i].append(nc)
        if not have_cache:
            return x, None
        new_grp = [jax.tree.map(lambda *xs: jnp.stack(xs), *ncs)
                   for ncs in acc]
    return x, {"pro": new_pro, "grp": new_grp}
