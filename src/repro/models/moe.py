"""Mixture-of-Experts MLP (deepseek-moe fine-grained, grok-1 coarse).

Routing is **branch-free** (paper P2): top-k selection feeds a sort-based
grouped matmul — tokens are argsorted by expert id, scattered into an
(E, C, D) capacity buffer, processed with three batched einsums, and
combined back with the gate weights. No `lax.cond`, no per-expert Python
branching; dropped tokens (over capacity) fall out via a select mask.

Sharding contract (see distribution.py): the token dim S is the local
per-device shard (the caller wraps this in shard_map over the data axes);
expert weights are tensor-parallel on the hidden dim F ('model' axis), so
the down-projection emits a partial sum the caller psums.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .layers import act_fn, linear


def moe_mlp(x: jax.Array, p: dict, *, top_k: int, act: str = "silu",
            capacity_factor: float = 1.25,
            router_in_f32: bool = True) -> jax.Array:
    """x: (S, D) local tokens. Returns (S, D) — partial over F-shards if
    the expert weights are F-sharded (caller psums).

    p: router (D, E); wg, wu (E, D, F); wd (E, F, D);
       optional shared_wg/wu/wd for always-on shared experts.
    """
    S, D = x.shape
    E = p["router"].shape[1]
    F = p["wg"].shape[-1]
    C = max(int(S * top_k / E * capacity_factor), 1)

    rx = x.astype(jnp.float32) if router_in_f32 else x
    logits = rx @ p["router"].astype(rx.dtype)            # (S, E)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, eidx = jax.lax.top_k(probs, top_k)             # (S, k)
    gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)

    # ---- sort-based dispatch (no branches, no host loops) ----
    flat_e = eidx.reshape(-1)                             # (S*k,)
    order = jnp.argsort(flat_e)                           # stable
    sorted_e = flat_e[order]
    token_of_slot = order // top_k
    # position of each slot within its expert group
    counts = jax.ops.segment_sum(jnp.ones_like(sorted_e), sorted_e,
                                 num_segments=E)          # (E,)
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(S * top_k) - starts[sorted_e]
    keep = pos_in_e < C                                   # capacity drop (P2)
    safe_pos = jnp.where(keep, pos_in_e, 0).astype(jnp.int32)

    xs = x[token_of_slot] * keep[:, None].astype(x.dtype)
    buf = jnp.zeros((E, C, D), x.dtype).at[sorted_e, safe_pos].add(
        xs, mode="drop")

    # ---- grouped expert MLP (three einsums over the E batch dim) ----
    h = act_fn(act)(jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(x.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["wu"].astype(x.dtype))
    y_buf = jnp.einsum("ecf,efd->ecd", h, p["wd"].astype(x.dtype))

    # ---- combine ----
    y_slots = y_buf[sorted_e, safe_pos] * keep[:, None].astype(x.dtype)
    inv = jnp.argsort(order)
    y = y_slots[inv].reshape(S, top_k, D)
    y = jnp.einsum("skd,sk->sd", y, gates.astype(x.dtype))

    if "shared_wg" in p:
        h = act_fn(act)(linear(x, p["shared_wg"])) * linear(x, p["shared_wu"])
        y = y + linear(h, p["shared_wd"])
    return y


def moe_mlp_ep(x: jax.Array, p: dict, *, top_k: int, n_devices: int,
               axis_name: str = "model", act: str = "silu",
               capacity_factor: float = 1.25) -> jax.Array:
    """Expert-parallel MoE (hillclimb variant, EXPERIMENTS §Perf).

    Call inside shard_map with tokens sharded over (dp, model) and the
    routed expert stacks sharded over 'model' on E (full hidden F per
    expert). Tokens travel to their experts' owners via all_to_all and
    back — O(S_local * k * D) wire bytes instead of replicating the
    (E, C, D) dispatch buffers across the model axis.

    p: router (D,E) + wg/wu/wd (E_local, D, F) + optional shared_* dense
    (replicated). Returns (S_local, D), complete (no psum needed).
    """
    S, D = x.shape
    E_local = p["wg"].shape[0]
    E = E_local * n_devices
    C = max(int(S * top_k / E * capacity_factor), 1)

    logits = x.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, top_k)              # (S, k)
    gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)

    flat_e = eidx.reshape(-1)
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    token_of_slot = order // top_k
    counts = jax.ops.segment_sum(jnp.ones_like(sorted_e), sorted_e,
                                 num_segments=E)
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(S * top_k) - starts[sorted_e]
    keep = pos_in_e < C
    safe_pos = jnp.where(keep, pos_in_e, 0).astype(jnp.int32)
    owner = (sorted_e // E_local).astype(jnp.int32)
    local_e = (sorted_e % E_local).astype(jnp.int32)

    xs = x[token_of_slot] * keep[:, None].astype(x.dtype)
    send = jnp.zeros((n_devices, E_local, C, D), x.dtype)
    send = send.at[owner, local_e, safe_pos].add(xs, mode="drop")

    # ship token slots to their expert owners (dim 0 = destination)
    recv = jax.lax.all_to_all(send, axis_name, split_axis=0,
                              concat_axis=0, tiled=True)
    # recv[s, e, c] = sender s's slots for my local expert e
    buf = recv.swapaxes(0, 1).reshape(E_local, n_devices * C, D)

    h = act_fn(act)(jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(x.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["wu"].astype(x.dtype))
    y_buf = jnp.einsum("ecf,efd->ecd", h, p["wd"].astype(x.dtype))

    back = jax.lax.all_to_all(
        y_buf.reshape(E_local, n_devices, C, D).swapaxes(0, 1),
        axis_name, split_axis=0, concat_axis=0, tiled=True)
    # back[d, e, c] = processed slot originally sent to device d's buffer
    y_slots = back[owner, local_e, safe_pos] * keep[:, None].astype(x.dtype)
    inv = jnp.argsort(order)
    y = y_slots[inv].reshape(S, top_k, D)
    y = jnp.einsum("skd,sk->sd", y, gates.astype(x.dtype))

    if "shared_wg" in p:
        h = act_fn(act)(linear(x, p["shared_wg"])) * linear(x, p["shared_wu"])
        y = y + linear(h, p["shared_wd"])
    return y


def aux_load_balance_loss(logits_f32: jax.Array, eidx: jax.Array,
                          n_experts: int, top_k: int) -> jax.Array:
    """Switch-style load-balance auxiliary loss (for training runs)."""
    probs = jax.nn.softmax(logits_f32, axis=-1)
    me = probs.mean(0)
    one_hot = jax.nn.one_hot(eidx, n_experts).sum(1)  # (S, E)
    ce = one_hot.mean(0) / top_k
    return n_experts * jnp.sum(me * ce)
