"""Model configuration for the 10 assigned LM-family architectures."""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                # query heads (0 => attention-free)
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None

    # layer pattern: one char per layer in the repeating group.
    #   A = global (full) attention block      L = local (sliding-window)
    #   M = Mamba2 block                       R = RWKV6 block
    #   S = *shared* attention block (Zamba2-style: same weights each use)
    pattern: str = "A"
    prologue: str = ""             # unscanned blocks before the groups
    window: Optional[int] = None   # SWA width for 'L' layers
    causal: bool = True            # False => encoder-only (no decode path)
    qkv_bias: bool = False

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: Optional[int] = None  # routed-expert hidden (deepseek fine-grained)
    capacity_factor: float = 1.25   # MoE token capacity per expert

    # SSM / RWKV
    ssm_state: int = 0
    ssm_head_dim: int = 64
    conv_kernel: int = 4

    # embeddings / misc
    rope_theta: float = 1e4
    rope_dim: Optional[int] = None  # original rotary dim when head_dim is
                                    # lane-padded (align.py); None = head_dim
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE
    embed_inputs: bool = True      # False => frontend stub feeds embeddings
    tie_embeddings: bool = False
    act: str = "silu"
    mlp_gated: bool = True         # False => plain 2-matrix MLP (hubert)
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"

    # training-time policies (the paper's P1 knob, TPU reading)
    scan_layers: bool = True       # scan over the repeating group (no unroll)
    remat: str = "full"            # 'none' | 'full'
    grad_accum: int = 1            # microbatches per optimizer step

    def __post_init__(self):
        if self.n_heads:
            object.__setattr__(self, "head_dim",
                               self.head_dim or self.d_model // self.n_heads)
        assert (self.n_layers - len(self.prologue)) % len(self.pattern) == 0, (
            f"{self.name}: n_layers {self.n_layers} minus prologue not a "
            f"multiple of pattern {self.pattern!r}")

    @property
    def n_groups(self) -> int:
        return (self.n_layers - len(self.prologue)) // len(self.pattern)

    @property
    def is_encoder(self) -> bool:
        return not self.causal

    @property
    def attn_free(self) -> bool:
        return all(c in "MR" for c in self.pattern + self.prologue)

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch can serve 500k context (SSM/hybrid/SWA)."""
        return all(c in "MRLS" or (c == "A" and False) for c in self.pattern) \
            or self.family in ("ssm", "hybrid")

    def smoke(self) -> "ModelConfig":
        """A reduced same-family config for CPU smoke tests."""
        pat_len = len(self.pattern)
        return replace(
            self,
            name=self.name + "-smoke",
            prologue=self.prologue[:1],
            n_layers=len(self.prologue[:1]) + pat_len * (1 if pat_len > 2
                                                         else 2),
            d_model=64,
            n_heads=min(self.n_heads, 4) if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=16 if self.n_heads else None,
            d_ff=128,
            moe_d_ff=32 if self.moe_d_ff else None,
            vocab_size=256,
            window=min(self.window, 8) if self.window else None,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            top_k=min(self.top_k, 2) if self.top_k else 0,
            capacity_factor=float(max(self.n_experts, 1)),  # dropless in smoke
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16,
            mrope_sections=(2, 3, 3) if self.mrope_sections else None,
            grad_accum=1,
            dtype="float32",
            remat="none",
        )
