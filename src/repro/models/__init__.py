from .config import ModelConfig
from .kernel_policy import DEFAULT_KERNELS, KernelPolicy
from .stack import Par, DEFAULT_PAR, init_params, init_cache, apply_stack
from .lm import (forward, loss_fn, make_train_step, make_eval_step,
                 make_prefill_step, make_decode_step, param_count,
                 active_param_count)
