"""Memory-efficient attention with a hand-written flash backward.

``jax.grad`` through a scanned online-softmax stores every block's
probability matrix (O(T^2) residuals — measured 270+ GiB/device for
qwen1.5-110b train_4k). The flash-attention backward fixes this: the
forward saves only (q, k, v, o, lse) = O(T), and the backward re-tiles
the score blocks. Both directions are plain (non-differentiated) scans,
so nothing inside them is retained.

Two variants:
  * ``flash_mha``  — full/causal attention, q-blocks x kv-blocks;
  * ``local_mha``  — sliding-window: every block reads one contiguous,
    statically-sized context slice, so compute AND memory are
    O(T * window) in both directions (never O(T^2)).

Layouts: q (B,T,H,Dh), k/v (B,T,Hkv,Dh), GQA by H = Hkv*G.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _mask(qpos, kpos, causal: bool, window: Optional[int]):
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        m &= (qpos[:, None] - kpos[None, :]) < window
    return m


# =========================================================== full/causal ====

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_mha(q, k, v, causal=True, window=None, scale=None,
              block_q=512, block_k=512):
    o, _ = _fwd(q, k, v, causal, window, scale, block_q, block_k)
    return o


def _fwd(q, k, v, causal, window, scale, block_q, block_k):
    B, T, H, Dh = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    bq, bk = min(block_q, T), min(block_k, Tk)
    assert T % bq == 0 and Tk % bk == 0
    nq, nk = T // bq, Tk // bk
    sc = scale if scale is not None else Dh ** -0.5
    qs = (q.astype(jnp.float32) * sc).astype(q.dtype)
    qb = jnp.moveaxis(qs.reshape(B, nq, bq, Hkv, G, Dh), 1, 0)

    def q_block(_, inp):
        q_i, iq = inp
        qpos = iq * bq + jnp.arange(bq)

        def kv_block(state, ik):
            m, l, acc = state
            k_j = jax.lax.dynamic_slice_in_dim(k, ik * bk, bk, 1)
            v_j = jax.lax.dynamic_slice_in_dim(v, ik * bk, bk, 1)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q_i, k_j,
                           preferred_element_type=jnp.float32)
            msk = _mask(qpos, ik * bk + jnp.arange(bk), causal, window)
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1, keepdims=True))
            p = jnp.exp(s - m_new)
            p = jnp.where(msk[None, None, None], p, 0.0)
            alpha = jnp.exp(m - m_new)
            l = alpha * l + p.sum(-1, keepdims=True)
            acc = alpha[..., 0, None] * acc + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(v_j.dtype), v_j,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        init = (jnp.full((B, Hkv, G, bq, 1), NEG_INF, jnp.float32),
                jnp.zeros((B, Hkv, G, bq, 1), jnp.float32),
                jnp.zeros((B, Hkv, G, bq, Dh), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_block, init, jnp.arange(nk))
        l_safe = jnp.where(l == 0, 1.0, l)
        o = (acc / l_safe).astype(q.dtype)
        lse = (m + jnp.log(l_safe))[..., 0]          # (B,Hkv,G,bq)
        return None, (jnp.moveaxis(o, 3, 1), lse)

    _, (ys, lses) = jax.lax.scan(q_block, None, (qb, jnp.arange(nq)))
    o = jnp.moveaxis(ys, 0, 1).reshape(B, T, H, Dh)
    lse = jnp.moveaxis(lses, 0, 3).reshape(B, Hkv, G, T)
    return o, lse


def _flash_fwd_rule(q, k, v, causal, window, scale, block_q, block_k):
    o, lse = _fwd(q, k, v, causal, window, scale, block_q, block_k)
    return o, (q, k, v, o, lse)


def _flash_bwd_rule(causal, window, scale, block_q, block_k, res, do):
    q, k, v, o, lse = res
    B, T, H, Dh = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    bq, bk = min(block_q, T), min(block_k, Tk)
    nq, nk = T // bq, Tk // bk
    sc = scale if scale is not None else Dh ** -0.5

    doh = do.reshape(B, T, Hkv, G, Dh)
    oh = o.reshape(B, T, Hkv, G, Dh)
    # delta_i = sum_d do_i * o_i   (B,Hkv,G,T)
    delta = jnp.einsum("bthgd,bthgd->bhgt", doh.astype(jnp.float32),
                       oh.astype(jnp.float32))
    qh = q.reshape(B, T, Hkv, G, Dh)

    def kv_step(dq_acc, ik):
        k_j = jax.lax.dynamic_slice_in_dim(k, ik * bk, bk, 1)
        v_j = jax.lax.dynamic_slice_in_dim(v, ik * bk, bk, 1)
        kpos = ik * bk + jnp.arange(bk)

        def q_step(carry, iq):
            dq_acc, dk_j, dv_j = carry
            q_i = jax.lax.dynamic_slice(
                qh, (0, iq * bq, 0, 0, 0), (B, bq, Hkv, G, Dh))
            do_i = jax.lax.dynamic_slice(
                doh, (0, iq * bq, 0, 0, 0), (B, bq, Hkv, G, Dh))
            lse_i = jax.lax.dynamic_slice(
                lse, (0, 0, 0, iq * bq), (B, Hkv, G, bq))
            dlt_i = jax.lax.dynamic_slice(
                delta, (0, 0, 0, iq * bq), (B, Hkv, G, bq))
            qpos = iq * bq + jnp.arange(bq)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q_i, k_j,
                           preferred_element_type=jnp.float32) * sc
            msk = _mask(qpos, kpos, causal, window)
            p = jnp.exp(s - lse_i[..., None])
            p = jnp.where(msk[None, None, None], p, 0.0)
            dv_j = dv_j + jnp.einsum("bhgqk,bqhgd->bkhd", p,
                                     do_i.astype(jnp.float32))
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", do_i, v_j,
                            preferred_element_type=jnp.float32)
            ds = p * (dp - dlt_i[..., None]) * sc
            dq_i = jnp.einsum("bhgqk,bkhd->bqhgd", ds, k_j,
                              preferred_element_type=jnp.float32)
            dk_j = dk_j + jnp.einsum("bhgqk,bqhgd->bkhd", ds,
                                     q_i.astype(jnp.float32))
            dq_acc = jax.lax.dynamic_update_slice(
                dq_acc,
                (jax.lax.dynamic_slice(
                    dq_acc, (0, iq * bq, 0, 0, 0), (B, bq, Hkv, G, Dh))
                 + dq_i.astype(dq_acc.dtype)),
                (0, iq * bq, 0, 0, 0))
            return (dq_acc, dk_j, dv_j), None

        zero_k = jnp.zeros((B, bk, Hkv, Dh), jnp.float32)
        (dq_acc, dk_j, dv_j), _ = jax.lax.scan(
            q_step, (dq_acc, zero_k, zero_k), jnp.arange(nq))
        return dq_acc, (dk_j, dv_j)

    dq0 = jnp.zeros((B, T, Hkv, G, Dh), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(kv_step, dq0, jnp.arange(nk))
    dk = jnp.moveaxis(dks, 0, 1).reshape(B, Tk, Hkv, Dh)
    dv = jnp.moveaxis(dvs, 0, 1).reshape(B, Tk, Hkv, Dh)
    return (dq.reshape(B, T, H, Dh).astype(q.dtype),
            dk.astype(k.dtype), dv.astype(v.dtype))


flash_mha.defvjp(_flash_fwd_rule, _flash_bwd_rule)


# ============================================================ local (SWA) ====

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def local_mha(q, k, v, window, scale=None, block_q=256):
    o, _ = _local_fwd(q, k, v, window, scale, block_q)
    return o


def _ctx_slice(x, start, ctx):
    return jax.lax.dynamic_slice(
        x, (0, start) + (0,) * (x.ndim - 2), (x.shape[0], ctx) + x.shape[2:])


def _local_fwd(q, k, v, window, scale, block_q):
    B, T, H, Dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    bq = min(block_q, T)
    assert T % bq == 0
    nq = T // bq
    ctx = min(window + bq, T)
    sc = scale if scale is not None else Dh ** -0.5
    qh = q.reshape(B, T, Hkv, G, Dh)

    def q_block(_, iq):
        qstart = iq * bq
        start = jnp.clip(qstart + bq - ctx, 0, T - ctx)
        q_i = _ctx_slice(qh, qstart, bq)
        k_j = _ctx_slice(k, start, ctx)
        v_j = _ctx_slice(v, start, ctx)
        qpos = qstart + jnp.arange(bq)
        kpos = start + jnp.arange(ctx)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q_i, k_j,
                       preferred_element_type=jnp.float32) * sc
        msk = (kpos[None, :] <= qpos[:, None]) & (
            qpos[:, None] - kpos[None, :] < window)
        s = jnp.where(msk[None, None, None], s, NEG_INF)
        mx = s.max(-1, keepdims=True)
        p = jnp.exp(s - mx)
        p = jnp.where(msk[None, None, None], p, 0.0)
        l = p.sum(-1, keepdims=True)
        l = jnp.where(l == 0, 1.0, l)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", (p / l).astype(v.dtype), v_j)
        lse = (mx + jnp.log(l))[..., 0]
        return None, (o, lse)

    _, (ys, lses) = jax.lax.scan(q_block, None, jnp.arange(nq))
    o = jnp.moveaxis(ys, 0, 1).reshape(B, T, H, Dh).astype(q.dtype)
    lse = jnp.moveaxis(lses, 0, 3).reshape(B, Hkv, G, T)
    return o, lse


def _local_fwd_rule(q, k, v, window, scale, block_q):
    o, lse = _local_fwd(q, k, v, window, scale, block_q)
    return o, (q, k, v, o, lse)


def _local_bwd_rule(window, scale, block_q, res, do):
    q, k, v, o, lse = res
    B, T, H, Dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    bq = min(block_q, T)
    nq = T // bq
    ctx = min(window + bq, T)
    sc = scale if scale is not None else Dh ** -0.5
    qh = q.reshape(B, T, Hkv, G, Dh)
    doh = do.reshape(B, T, Hkv, G, Dh)
    oh = o.reshape(B, T, Hkv, G, Dh)
    delta = jnp.einsum("bthgd,bthgd->bhgt", doh.astype(jnp.float32),
                       oh.astype(jnp.float32))

    def recompute_p(q_i, k_j, lse_i, qpos, kpos):
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q_i, k_j,
                       preferred_element_type=jnp.float32) * sc
        msk = (kpos[None, :] <= qpos[:, None]) & (
            qpos[:, None] - kpos[None, :] < window)
        p = jnp.exp(s - lse_i[..., None])
        return jnp.where(msk[None, None, None], p, 0.0)

    # pass 1: dq per q-block (same slices as forward)
    def dq_block(_, iq):
        qstart = iq * bq
        start = jnp.clip(qstart + bq - ctx, 0, T - ctx)
        q_i = _ctx_slice(qh, qstart, bq)
        do_i = _ctx_slice(doh, qstart, bq)
        k_j = _ctx_slice(k, start, ctx)
        v_j = _ctx_slice(v, start, ctx)
        lse_i = jax.lax.dynamic_slice(lse, (0, 0, 0, qstart),
                                      (B, Hkv, G, bq))
        dlt_i = jax.lax.dynamic_slice(delta, (0, 0, 0, qstart),
                                      (B, Hkv, G, bq))
        qpos = qstart + jnp.arange(bq)
        kpos = start + jnp.arange(ctx)
        p = recompute_p(q_i, k_j, lse_i, qpos, kpos)
        dp = jnp.einsum("bqhgd,bkhd->bhgqk", do_i, v_j,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - dlt_i[..., None]) * sc
        dq_i = jnp.einsum("bhgqk,bkhd->bqhgd", ds, k_j,
                          preferred_element_type=jnp.float32)
        return None, dq_i

    _, dqs = jax.lax.scan(dq_block, None, jnp.arange(nq))
    dq = jnp.moveaxis(dqs, 0, 1).reshape(B, T, H, Dh)

    # pass 2: dk/dv per kv-block; q rows that can see block j live in
    # [jstart, jstart + bq + window) — one contiguous static slice.
    bkv = bq
    nkv = T // bkv
    qctx = min(window + bkv, T)

    def dkv_block(_, jk):
        kstart = jk * bkv
        qs = jnp.clip(kstart, 0, T - qctx)
        k_j = _ctx_slice(k, kstart, bkv)
        v_j = _ctx_slice(v, kstart, bkv)
        q_i = _ctx_slice(qh, qs, qctx)
        do_i = _ctx_slice(doh, qs, qctx)
        lse_i = jax.lax.dynamic_slice(lse, (0, 0, 0, qs), (B, Hkv, G, qctx))
        dlt_i = jax.lax.dynamic_slice(delta, (0, 0, 0, qs),
                                      (B, Hkv, G, qctx))
        qpos = qs + jnp.arange(qctx)
        kpos = kstart + jnp.arange(bkv)
        p = recompute_p(q_i, k_j, lse_i, qpos, kpos)        # (B,h,g,qctx,bkv)
        dv_j = jnp.einsum("bhgqk,bqhgd->bkhd", p, do_i.astype(jnp.float32))
        dp = jnp.einsum("bqhgd,bkhd->bhgqk", do_i, v_j,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - dlt_i[..., None]) * sc
        dk_j = jnp.einsum("bhgqk,bqhgd->bkhd", ds, q_i.astype(jnp.float32))
        return None, (dk_j, dv_j)

    _, (dks, dvs) = jax.lax.scan(dkv_block, None, jnp.arange(nkv))
    dk = jnp.moveaxis(dks, 0, 1).reshape(B, T, Hkv, Dh)
    dv = jnp.moveaxis(dvs, 0, 1).reshape(B, T, Hkv, Dh)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))


local_mha.defvjp(_local_fwd_rule, _local_bwd_rule)
