"""P4 lane-alignment pass at LM scale.

The paper pads conv output channels to the SIMD width (multiples of 4
for SSSE3) with zero filters. The TPU reading: pad *head_dim* to a lane
multiple (128) with zero columns so the attention tensors shard on the
'model' axis and land on aligned MXU tiles.

Zero-padding is **exact**: padded q/k dims contribute 0 to every logit,
padded v dims produce zero outputs that meet zero rows of ``wo``.
``pad_head_dim`` transforms trained params; running the padded params
under ``replace(cfg, head_dim=new_dh)`` computes the identical function
(tested in tests/test_align.py).

h2o-danube-3-4b is the motivating case: head_dim=120 divides neither 16
(TP axis) nor 128 (lanes), so the baseline replicates every attention
tensor across the model axis; 120→128 unlocks Dh-sharding.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .config import ModelConfig


def _pad_head_cols(w, n_heads, dh_old, dh_new, *, rotary: bool,
                   scale: float = 1.0):
    """Pad the per-head output columns of w (..., D, H*dh_old).

    ``rotary=True`` pads in rope-pair space — each half of the head dim
    grows separately, so the (i, i + dh/2) rotation pairing of the
    original dims is preserved."""
    *lead, d, hd = w.shape
    w = w.reshape(*lead, d, n_heads, dh_old) * scale
    pad = dh_new - dh_old
    if rotary:
        h_old, h_new = dh_old // 2, dh_new // 2
        w = w.reshape(*lead, d, n_heads, 2, h_old)
        w = jnp.pad(w, [(0, 0)] * (w.ndim - 1) + [(0, h_new - h_old)])
    else:
        w = jnp.pad(w, [(0, 0)] * (w.ndim - 1) + [(0, pad)])
    return w.reshape(*lead, d, n_heads * dh_new)


def _pad_head_rows(w, n_heads, dh_old, dh_new):
    """Pad the per-head input rows of wo (..., H*dh_old, D)."""
    *lead, hd, d = w.shape
    w = w.reshape(*lead, n_heads, dh_old, d)
    w = jnp.pad(w, [(0, 0)] * (w.ndim - 3) + [(0, 0),
                                              (0, dh_new - dh_old), (0, 0)])
    return w.reshape(*lead, n_heads * dh_new, d)


def _pad_bias(b, n_heads, dh_old, dh_new, *, rotary: bool,
              scale: float = 1.0):
    *lead, hd = b.shape
    b = b.reshape(*lead, n_heads, dh_old) * scale
    if rotary:
        h_old, h_new = dh_old // 2, dh_new // 2
        b = b.reshape(*lead, n_heads, 2, h_old)
        b = jnp.pad(b, [(0, 0)] * (b.ndim - 1) + [(0, h_new - h_old)])
    else:
        b = jnp.pad(b, [(0, 0)] * (b.ndim - 1) + [(0, dh_new - dh_old)])
    return b.reshape(*lead, n_heads * dh_new)


def pad_head_dim(params, cfg: ModelConfig, new_dh: int):
    """Returns (padded_params, new_cfg). Function-preserving:
    * q/k pad in rope-pair space + ``rope_dim`` pins the original
      frequency ladder (padded dims stay zero under rotation);
    * wq/bq absorb sqrt(new/old) so the softmax scale is unchanged;
    * v/wo pad plainly (v is not rotated)."""
    old = cfg.head_dim
    assert new_dh >= old and new_dh % 2 == 0 and old % 2 == 0
    assert cfg.mrope_sections is None, "mrope sections need their own pad"
    H, Hkv = cfg.n_heads, cfg.n_kv_heads
    qscale = (new_dh / old) ** 0.5  # flash scales by 1/sqrt(Dh_new)

    def fix_attn(p):
        q = dict(p)
        q["wq"] = _pad_head_cols(p["wq"], H, old, new_dh, rotary=True,
                                 scale=qscale)
        q["wk"] = _pad_head_cols(p["wk"], Hkv, old, new_dh, rotary=True)
        q["wv"] = _pad_head_cols(p["wv"], Hkv, old, new_dh, rotary=False)
        q["wo"] = _pad_head_rows(p["wo"], H, old, new_dh)
        if "bq" in p:
            q["bq"] = _pad_bias(p["bq"], H, old, new_dh, rotary=True,
                                scale=qscale)
            q["bk"] = _pad_bias(p["bk"], Hkv, old, new_dh, rotary=True)
            q["bv"] = _pad_bias(p["bv"], Hkv, old, new_dh, rotary=False)
        return q

    def walk(node):
        if isinstance(node, dict):
            if "wq" in node:
                return fix_attn(node)
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return node

    new_cfg = dataclasses.replace(cfg, head_dim=new_dh, rope_dim=old)
    return walk(params), new_cfg
