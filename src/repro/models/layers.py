"""Core LM building blocks (pure functional JAX).

Layout conventions:
  activations  (B, T, D)          heads (B, T, H, Dh)
  attn weights (D, H*Dh) etc.     all params live in plain dicts

NNCG principle mapping (see DESIGN.md §3): every mask is iota+select
(P2), every structural decision (pattern, window, group sizes) is a
trace-time constant (P3), head/lane dims are 128-aligned by the configs
(P4), and the layer stack is scanned or unrolled per LoopPolicy (P1).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ------------------------------------------------------------------ norms ----

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * scale + bias).astype(x.dtype)


def group_norm_heads(x, scale, eps: float = 1e-5):
    """Per-head LayerNorm (RWKV6 wkv output norm). x: (..., H, N)."""
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


# ------------------------------------------------------------------- rope ----

def rope(x: jax.Array, positions: jax.Array, theta: float = 1e4,
         rope_dim: Optional[int] = None) -> jax.Array:
    """Rotary embedding; x (B, T, H, Dh), positions (B, T) int32.
    ``rope_dim``: the *original* head_dim when Dh has been lane-padded
    (P4 alignment) — keeps the frequency ladder of the unpadded model so
    padding is function-preserving."""
    dh = x.shape[-1]
    half = dh // 2
    base_half = (rope_dim or dh) // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / base_half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (B,T,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def mrope(x: jax.Array, positions3: jax.Array,
          sections: Tuple[int, int, int], theta: float = 1e4) -> jax.Array:
    """Qwen2-VL multimodal RoPE: head_dim/2 freqs split into (t, h, w)
    sections, each rotated by its own position stream.
    x (B,T,H,Dh); positions3 (3,B,T)."""
    dh = x.shape[-1]
    half = dh // 2
    assert sum(sections) == half, (sections, half)
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang_streams = positions3.astype(jnp.float32)[..., None] * freqs  # (3,B,T,half)
    sec_id = jnp.repeat(jnp.arange(3), jnp.array(sections),
                        total_repeat_length=half)  # (half,)
    # per-channel stream select as a one-hot mix (P2: no gather/branch)
    onehot = jax.nn.one_hot(sec_id, 3, dtype=jnp.float32)  # (half, 3)
    ang = jnp.einsum("sbtf,fs->btf", ang_streams, onehot)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------ linear ----

def linear(x, w, b=None):
    y = jnp.einsum("...d,df->...f", x, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": functools.partial(
        jax.nn.gelu, approximate=True), "relu": jax.nn.relu}[name]


def gated_mlp(x, p, act: str = "silu"):
    h = act_fn(act)(linear(x, p["wg"]))
    if "wu" in p:
        h = h * linear(x, p["wu"])
    return linear(h, p["wd"])


# -------------------------------------------------------------- attention ----

def flash_attention_jax(q, k, v, *, causal=True, window=None, scale=None,
                        q_offset=0, block_q=512, block_k=512):
    """Blockwise online-softmax attention in pure jnp (lax.scan tiling).

    q (B,Tq,H,Dh); k,v (B,Tk,Hkv,Dh). Used on the dry-run/XLA path; the
    Pallas kernel implements the same math for real TPU execution.
    """
    B, Tq, H, Dh = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    bq, bk = min(block_q, Tq), min(block_k, Tk)
    assert Tq % bq == 0 and Tk % bk == 0
    nq, nk = Tq // bq, Tk // bk
    scale = scale if scale is not None else Dh ** -0.5
    qb = jnp.moveaxis(q.reshape(B, nq, bq, Hkv, G, Dh), 1, 0)

    def q_block(carry, inp):
        del carry
        q_i, iq = inp  # (B,bq,Hkv,G,Dh)
        qpos = q_offset + iq * bq + jnp.arange(bq)

        def kv_block(state, ik):
            m, l, acc = state
            k_j = jax.lax.dynamic_slice_in_dim(k, ik * bk, bk, 1)
            v_j = jax.lax.dynamic_slice_in_dim(v, ik * bk, bk, 1)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q_i, k_j,
                           preferred_element_type=jnp.float32) * scale
            kpos = ik * bk + jnp.arange(bk)
            mask = jnp.ones((bq, bk), bool)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                mask &= (qpos[:, None] - kpos[None, :]) < window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1, keepdims=True))
            p = jnp.exp(s - m_new)
            p = jnp.where(mask[None, None, None], p, 0.0)
            alpha = jnp.exp(m - m_new)
            l = alpha * l + p.sum(-1, keepdims=True)
            acc = alpha[..., 0, None] * acc + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(v_j.dtype), v_j,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        init = (jnp.full((B, Hkv, G, bq, 1), NEG_INF, jnp.float32),
                jnp.zeros((B, Hkv, G, bq, 1), jnp.float32),
                jnp.zeros((B, Hkv, G, bq, Dh), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_block, init, jnp.arange(nk))
        l = jnp.where(l == 0, 1.0, l)
        o = (acc / l).astype(q.dtype)  # (B,Hkv,G,bq,Dh)
        return None, jnp.moveaxis(o, 3, 1)  # (B,bq,Hkv,G,Dh)

    _, ys = jax.lax.scan(q_block, None, (qb, jnp.arange(nq)))
    out = jnp.moveaxis(ys, 0, 1)  # (B,nq,bq,Hkv,G,Dh)
    return out.reshape(B, Tq, H, Dh)


def local_attention_jax(q, k, v, *, window: int, scale=None, block_q=256):
    """Exact causal sliding-window attention: each q block of ``bq`` rows
    reads only the ``window + bq`` keys that can be visible to it —
    compute is O(T * window), never O(T^2)."""
    B, T, H, Dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    bq = min(block_q, T)
    assert T % bq == 0
    nq = T // bq
    ctx = window + bq
    scale = scale if scale is not None else Dh ** -0.5
    if T < ctx:  # short sequence: plain flash with window mask
        return flash_attention_jax(q, k, v, causal=True, window=window,
                                   scale=scale)
    qb = jnp.moveaxis(q.reshape(B, nq, bq, Hkv, G, Dh), 1, 0)

    def q_block(_, inp):
        q_i, iq = inp
        qstart = iq * bq
        start = jnp.clip(qstart + bq - ctx, 0, T - ctx)
        k_j = jax.lax.dynamic_slice_in_dim(k, start, ctx, 1)
        v_j = jax.lax.dynamic_slice_in_dim(v, start, ctx, 1)
        qpos = qstart + jnp.arange(bq)
        kpos = start + jnp.arange(ctx)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q_i, k_j,
                       preferred_element_type=jnp.float32) * scale
        mask = (kpos[None, :] <= qpos[:, None]) & (
            qpos[:, None] - kpos[None, :] < window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        p = jnp.where(mask[None, None, None], p, 0.0)
        o = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v_j.dtype), v_j)
        return None, jnp.moveaxis(o.astype(q.dtype), 3, 1)

    _, ys = jax.lax.scan(q_block, None, (qb, jnp.arange(nq)))
    return jnp.moveaxis(ys, 0, 1).reshape(B, T, H, Dh)


def decode_attention_jax(q, k_cache, v_cache, pos, *, window=None,
                         ring=False, scale=None):
    """One-token attention against a cache.

    q (B,1,H,Dh); k_cache/v_cache (B,S,Hkv,Dh); pos scalar int32 — the
    position of the *new* token (cache already contains it at its slot).
    ``ring=True`` means the cache is a rolling buffer of size S=window.
    """
    B, S, Hkv, Dh = k_cache.shape
    H = q.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else Dh ** -0.5
    qh = q.reshape(B, Hkv, G, Dh)
    s = jnp.einsum("bhgd,bshd->bhgs", qh, k_cache,
                   preferred_element_type=jnp.float32) * scale
    slots = jnp.arange(S)
    if ring:
        # slot s holds absolute position: pos - ((pos - s) mod S)
        slot_pos = pos - jnp.mod(pos - slots, S)
    else:
        slot_pos = slots
    mask = (slot_pos >= 0) & (slot_pos <= pos)
    if window is not None:
        mask &= (pos - slot_pos) < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask[None, None, None], p, 0.0)
    o = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache)
    return o.reshape(B, 1, H, Dh).astype(q.dtype)
