"""Deterministic synthetic data pipelines.

Determinism contract (fault tolerance): every batch is a pure function
of ``(seed, step, shard_index)`` — any host can recompute any other
host's shard after a restart or topology change (straggler/elastic
story, DESIGN.md §9), and a resumed run consumes *exactly* the stream it
would have seen uninterrupted.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from queue import Queue
from typing import Dict, Iterator, Optional

import numpy as np

from repro.models.config import ModelConfig


@dataclass(frozen=True)
class TokenStreamConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_shards: int = 1
    shard: int = 0


def _rng_for(seed: int, step: int, shard: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([seed, step, shard]))


def token_batch(tc: TokenStreamConfig, step: int) -> Dict[str, np.ndarray]:
    """Markov-ish synthetic tokens with learnable structure (so training
    loss visibly falls): token_{t+1} = (a * token_t + b) % V with noise."""
    assert tc.global_batch % tc.n_shards == 0
    b_local = tc.global_batch // tc.n_shards
    rng = _rng_for(tc.seed, step, tc.shard)
    V = tc.vocab_size
    a = 31
    start = rng.integers(0, V, (b_local, 1))
    steps = np.arange(tc.seq_len + 1)
    seq = (start * pow(a, 1, V) + 0)  # placeholder, filled below
    seq = np.empty((b_local, tc.seq_len + 1), np.int64)
    seq[:, 0] = start[:, 0]
    noise = rng.random((b_local, tc.seq_len)) < 0.05
    rand_tok = rng.integers(0, V, (b_local, tc.seq_len))
    for t in range(tc.seq_len):
        nxt = (seq[:, t] * a + 7) % V
        seq[:, t + 1] = np.where(noise[:, t], rand_tok[:, t], nxt)
    return {"tokens": seq[:, :-1].astype(np.int32),
            "labels": seq[:, 1:].astype(np.int32)}


def token_stream(tc: TokenStreamConfig, start_step: int = 0
                 ) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield token_batch(tc, step)
        step += 1


class Prefetcher:
    """Background-thread prefetch (double buffering — overlap host data
    generation with device compute)."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: Queue = Queue(maxsize=depth)
        self._it = it
        self._done = object()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item


# ------------------------------------------------------ synthetic balls -----

def ball_image_batch(n: int, *, res: int = 16, seed: int = 0, step: int = 0):
    """Procedural stand-in for the paper's RoboCup ball dataset: white
    discs with dark spots on noisy background vs. pure noise/edges.
    Returns (images (n,res,res,1) float32 in [0,1], labels (n,) {0,1})."""
    rng = _rng_for(seed, step, 0)
    labels = rng.integers(0, 2, n)
    imgs = rng.normal(0.35, 0.15, (n, res, res, 1)).astype(np.float32)
    yy, xx = np.mgrid[0:res, 0:res]
    for i in range(n):
        if labels[i]:
            cx, cy = rng.uniform(res * 0.3, res * 0.7, 2)
            r = rng.uniform(res * 0.25, res * 0.45)
            disc = ((xx - cx) ** 2 + (yy - cy) ** 2) < r ** 2
            imgs[i, :, :, 0][disc] = rng.uniform(0.8, 1.0)
            n_spots = rng.integers(2, 5)
            for _ in range(n_spots):
                sx, sy = rng.uniform(cx - r / 2, cx + r / 2), \
                         rng.uniform(cy - r / 2, cy + r / 2)
                spot = ((xx - sx) ** 2 + (yy - sy) ** 2) < (r / 4) ** 2
                imgs[i, :, :, 0][spot & disc] = rng.uniform(0.0, 0.2)
        else:
            # distractor: bright edge/corner blob (not a disc)
            if rng.random() < 0.5:
                w = rng.integers(2, 6)
                imgs[i, :w, :, 0] += rng.uniform(0.4, 0.6)
    return np.clip(imgs, 0, 1), labels.astype(np.int32)


def camera_frame_batch(n: int, shape, *, seed: int = 0,
                       blur_passes: int = 2, blur_k: int = 5) -> np.ndarray:
    """Synthetic camera-like frames for int8 calibration: smooth,
    bounded [0, 1] images with per-frame brightness/contrast jitter.

    The paper's CNNs consume camera images; calibrating activation
    ranges on unbounded white noise (the old benchmark default) is
    unrepresentative of deployment and inflates every per-tensor range.
    These frames are spatially-correlated uniform noise (separable box
    blur), contrast-stretched per frame, then gain/offset-jittered so
    the calibration set covers a spread of exposure conditions.
    Deterministic in ``(seed)``; returns ``(n, *shape)`` float32."""
    rng = _rng_for(seed, 0, 1)
    h, w, c = shape
    imgs = rng.uniform(0, 1, (n, h, w, c)).astype(np.float32)
    half = blur_k // 2
    for _ in range(blur_passes):
        # separable box blur via padded cumulative sums (no scipy dep)
        s = np.cumsum(np.pad(imgs, ((0, 0), (half + 1, half), (0, 0),
                                    (0, 0)), mode="edge"), axis=1)
        imgs = (s[:, blur_k:] - s[:, :-blur_k]) / blur_k
        s = np.cumsum(np.pad(imgs, ((0, 0), (0, 0), (half + 1, half),
                                    (0, 0)), mode="edge"), axis=2)
        imgs = (s[:, :, blur_k:] - s[:, :, :-blur_k]) / blur_k
    mn = imgs.min(axis=(1, 2, 3), keepdims=True)
    mx = imgs.max(axis=(1, 2, 3), keepdims=True)
    imgs = (imgs - mn) / np.maximum(mx - mn, 1e-6)
    gain = rng.uniform(0.6, 1.0, (n, 1, 1, 1)).astype(np.float32)
    offset = rng.uniform(0.0, 0.3, (n, 1, 1, 1)).astype(np.float32)
    return np.clip(imgs * gain + offset, 0.0, 1.0).astype(np.float32)
